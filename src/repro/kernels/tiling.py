"""Static tile planning for the streaming pool kernels.

The pool pack/unpack kernels stream the gradient pool through VMEM in
~512KiB tiles instead of holding it resident (the whole-pool variants
stopped scaling exactly at AlexNet size — ROADMAP's retired 4M-element
fallback). Because the pool layout is compile-time static (the segment
table in ``GradientPool``), the entire DMA schedule is too: this module
intersects every leaf segment with every tile it touches and emits a flat
list of static copies — a segment that straddles a tile boundary simply
contributes one copy per tile it crosses. The kernels unroll the schedule
into ``pl.when(program_id == tile)`` blocks, so the compiler sees a fixed
per-tile copy list with no scatter/gather indexing at all.

Schedule size is O(num_leaves + num_tiles): each tile boundary splits at
most one segment, so a pool with L leaves and T tiles produces at most
L + T - 1 copies (plus the trailing-padding zero fills).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax.numpy as jnp

# Per-operand tile target: comfortably inside VMEM (~16MiB/core) with
# double-buffering headroom, same sizing rule as chunk_l1norm.
TILE_TARGET_BYTES = 512 * 1024


@dataclasses.dataclass(frozen=True)
class TileCopy:
    """One static copy between a leaf segment and a tile-local range.

    ``leaf`` indexes the segment table; ``src_lo`` is the offset inside
    that leaf, ``dst_lo`` the offset inside tile ``tile``'s VMEM slot.
    For zero fills (pool tail padding) ``leaf`` is -1 and ``src_lo`` 0.
    """

    leaf: int
    tile: int
    src_lo: int
    dst_lo: int
    elems: int


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """A pool's static streaming schedule: tiling plus the copy list."""

    pool_size: int
    tile_elems: int
    num_tiles: int
    copies: Tuple[TileCopy, ...]   # leaf <-> tile segment traffic
    fills: Tuple[TileCopy, ...]    # zero fills for the padding tail

    @property
    def num_copies(self) -> int:
        return len(self.copies)


def pick_tile(pool_size: int, chunk_elems: int, itemsize: int,
              target_bytes: int = TILE_TARGET_BYTES) -> int:
    """Tile size in elements. With a chunk census the tile is a whole
    number of chunks (rows x chunk_elems) so every tile emits complete
    per-chunk norms; without one it is a plain ~target_bytes range. The
    tile need NOT divide the pool — the final tile may be ragged (Pallas
    masks the edge block) and the copy schedule is clipped to the pool.
    """
    assert pool_size > 0 and itemsize > 0
    if chunk_elems > 0:
        assert pool_size % chunk_elems == 0, (pool_size, chunk_elems)
        num_chunks = pool_size // chunk_elems
        rows = max(1, target_bytes // (chunk_elems * itemsize))
        return min(rows, num_chunks) * chunk_elems
    return min(pool_size, max(1, target_bytes // itemsize))


@functools.lru_cache(maxsize=None)
def tile_schedule(offsets: Tuple[int, ...], sizes: Tuple[int, ...],
                  pool_size: int, tile_elems: int) -> TilePlan:
    """Intersect every segment with the tiles it spans (all static)."""
    assert len(offsets) == len(sizes)
    assert 0 < tile_elems
    num_tiles = -(-pool_size // tile_elems)  # cdiv
    copies = []
    for leaf, (off, sz) in enumerate(zip(offsets, sizes)):
        if sz == 0:
            continue
        assert off + sz <= pool_size, (off, sz, pool_size)
        for tile in range(off // tile_elems, (off + sz - 1) // tile_elems + 1):
            lo = max(off, tile * tile_elems)
            hi = min(off + sz, (tile + 1) * tile_elems)
            copies.append(TileCopy(leaf=leaf, tile=tile, src_lo=lo - off,
                                   dst_lo=lo - tile * tile_elems,
                                   elems=hi - lo))
    covered = (offsets[-1] + sizes[-1]) if sizes else 0
    fills = []
    if covered < pool_size:  # CSC chunk-alignment padding at the tail
        for tile in range(covered // tile_elems, num_tiles):
            lo = max(covered, tile * tile_elems)
            hi = min(pool_size, (tile + 1) * tile_elems)
            fills.append(TileCopy(leaf=-1, tile=tile, src_lo=0,
                                  dst_lo=lo - tile * tile_elems,
                                  elems=hi - lo))
    return TilePlan(pool_size=pool_size, tile_elems=tile_elems,
                    num_tiles=num_tiles, copies=tuple(copies),
                    fills=tuple(fills))


def itemsize(dtype) -> int:
    return jnp.dtype(dtype).itemsize
