from repro.data.pipeline import DataPipeline, Prefetcher
from repro.data.synthetic import SyntheticLM
