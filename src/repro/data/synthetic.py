"""Deterministic synthetic token pipeline.

Produces a learnable (non-iid-noise) token stream so end-to-end convergence
tests are meaningful: tokens follow a order-2 Markov chain derived from a
fixed key, so cross-entropy has substantial headroom below log(V).

Determinism + skip-ahead: batch t is a pure function of (seed, step), so a
restarted/resharded trainer resumes bit-identically at any step without
replaying the stream — the property fault-tolerant restart relies on.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seed: int = 0,
                 num_codebooks: int = 0, branching: int = 4):
        self.vocab = vocab_size
        self.seed = seed
        self.num_codebooks = num_codebooks
        # Fixed random transition table: each (prev) state has `branching`
        # likely successors.
        rng = np.random.RandomState(seed)
        self.succ = jnp.asarray(
            rng.randint(0, vocab_size, size=(vocab_size, branching)),
            jnp.int32)
        self.branching = branching

    def _sequence(self, key: jax.Array, seq_len: int) -> jax.Array:
        k0, k1 = jax.random.split(key)
        start = jax.random.randint(k0, (), 0, self.vocab, jnp.int32)
        choices = jax.random.randint(k1, (seq_len,), 0, self.branching,
                                     jnp.int32)

        def step(tok, choice):
            nxt = self.succ[tok, choice]
            return nxt, nxt

        _, toks = jax.lax.scan(step, start, choices)
        return toks

    def batch(self, step: int, batch_size: int, seq_len: int,
              shard: int = 0, num_shards: int = 1) -> Dict[str, jax.Array]:
        """The shard-local batch for global step ``step``.

        Each (step, shard, row) triple folds into an independent key, so
        shards never overlap and any shard count yields the same global
        sample set — the elasticity invariant (tested).
        """
        base = jax.random.PRNGKey(self.seed)
        base = jax.random.fold_in(base, step)
        row_ids = shard * batch_size + jnp.arange(batch_size)
        keys = jax.vmap(lambda r: jax.random.fold_in(base, r))(row_ids)
        toks = jax.vmap(lambda k: self._sequence(k, seq_len + 1))(keys)
        tokens, labels = toks[:, :-1], toks[:, 1:]
        if self.num_codebooks > 1:
            tokens = jnp.tile(tokens[..., None], (1, 1, self.num_codebooks))
            labels = jnp.tile(labels[..., None], (1, 1, self.num_codebooks))
        return {"tokens": tokens, "labels": labels}
