"""Sharded data pipeline with host-side prefetch.

Wraps a batch source (``SyntheticLM`` here; a real deployment would plug in
a file-backed loader with the same (step, shard)-pure interface) and
prefetches ahead of the training loop on a background thread. Because
batches are pure functions of (step, shard), skip-ahead after a
checkpoint-restore is O(1): just start asking for the restored step.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional


class Prefetcher:
    def __init__(self, fetch: Callable[[int], Any], start_step: int,
                 depth: int = 2):
        self._fetch = fetch
        self._queue: "queue.Queue" = queue.Queue(maxsize=depth)
        self._next_step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next_step
        while not self._stop.is_set():
            try:
                item = (step, self._fetch(step))
            except Exception as e:  # surface loader errors to the consumer
                self._queue.put((step, e))
                return
            while not self._stop.is_set():
                try:
                    self._queue.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self, expected_step: int) -> Any:
        step, item = self._queue.get()
        if isinstance(item, Exception):
            raise item
        assert step == expected_step, (
            f"pipeline out of sync: got {step}, expected {expected_step}")
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._queue.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


class DataPipeline:
    """Step-indexed, shard-aware pipeline with prefetch + skip-ahead."""

    def __init__(self, source, batch_size: int, seq_len: int,
                 shard: int = 0, num_shards: int = 1, prefetch: int = 2):
        self.source = source
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.shard = shard
        self.num_shards = num_shards
        self._prefetch_depth = prefetch
        self._prefetcher: Optional[Prefetcher] = None
        self._step = 0

    def _fetch(self, step: int):
        return self.source.batch(step, self.batch_size, self.seq_len,
                                 shard=self.shard,
                                 num_shards=self.num_shards)

    def start(self, step: int = 0):
        self.stop()
        self._step = step
        self._prefetcher = Prefetcher(self._fetch, step,
                                      depth=self._prefetch_depth)

    def next(self) -> Dict[str, Any]:
        if self._prefetcher is None:
            self.start(self._step)
        batch = self._prefetcher.get(self._step)
        self._step += 1
        return batch

    def next_at(self, step: int) -> Dict[str, Any]:
        """Fetch THE batch for ``step`` — the consumer's step index is
        authoritative, not the pipeline's internal cursor. When they
        agree this is ``next()``; when they don't (a supervisor replay
        after a restore the pipeline didn't hear about) the prefetcher
        restarts at ``step`` so the replayed step re-reads exactly the
        batch it saw the first time. Batches are pure in (step, shard),
        so a resync costs one prefetch restart, never wrong data."""
        if self._prefetcher is None or self._step != step:
            self.start(step)
        return self.next()

    def skip_to(self, step: int):
        """O(1) skip-ahead (restore-from-checkpoint path)."""
        self.start(step)

    def stop(self):
        if self._prefetcher is not None:
            self._prefetcher.close()
            self._prefetcher = None
