from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint import reshard
