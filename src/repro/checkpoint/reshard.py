"""Elastic resharding: restore a checkpoint onto a *different* mesh.

Checkpoints store full logical arrays (manager.py), so resharding is a
placement problem, not a data-transform problem: given the new mesh and the
architecture's sharding rules, ``place`` device_puts every leaf with its
NamedSharding. The pool-space optimizer/GradientFlow state is mesh-
independent by construction (1-D logical vectors replicated across data
axes), so elastic scaling changes *only* the data-parallel degree — the
global batch is re-split and the data pipeline's (step, shard)-pure
indexing keeps sample order consistent.

``plan`` validates feasibility first (divisibility of sharded dims on the
new mesh) so a supervisor can decide between meshes before moving bytes.
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def plan(abstract_state: Any, pspecs: Any, mesh: Mesh) -> List[str]:
    """Returns a list of problems (empty = resharding is feasible)."""
    problems = []
    flat_s = jax.tree_util.tree_leaves(abstract_state)
    flat_p = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    axis_sizes = dict(mesh.shape)  # works for Mesh and AbstractMesh
    for leaf, spec in zip(flat_s, flat_p):
        for dim, names in enumerate(spec):
            if names is None:
                continue
            group = names if isinstance(names, tuple) else (names,)
            total = int(np.prod([axis_sizes[n] for n in group]))
            if dim >= len(leaf.shape) or leaf.shape[dim] % total != 0:
                problems.append(
                    f"dim {dim} of shape {leaf.shape} not divisible by "
                    f"{total} ({group})")
    return problems


def place(state: Any, shardings: Any) -> Any:
    """device_put every leaf with its (new-mesh) sharding."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), state, shardings)


def reshard_hg(old_hg: np.ndarray, new_num_data: int) -> np.ndarray:
    """Re-distribute CSC's per-shard historical gradients across a new
    data-parallel degree.

    The algorithm only ever consumes hg additively before a sum-reduce
    (Algorithm 1 line 7 followed by the allreduce), so any transform that
    preserves the *column-wise total* is semantically exact. We split the
    total evenly across the new shards to keep per-shard magnitudes (and
    the L1 norm census) balanced.
    """
    total = np.asarray(old_hg).sum(axis=0, keepdims=True)
    return np.tile(total / new_num_data, (new_num_data, 1))


def reshard_batch_split(global_batch: int, old_shards: int,
                        new_shards: int) -> Tuple[int, int]:
    """(old_per_shard, new_per_shard) batch sizes after elastic remesh."""
    assert global_batch % old_shards == 0
    assert global_batch % new_shards == 0, (
        f"global batch {global_batch} not divisible by {new_shards} shards")
    return global_batch // old_shards, global_batch // new_shards
