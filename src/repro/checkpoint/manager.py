"""Checkpointing: async, atomic, retained, mesh-agnostic.

Layout per step:  <dir>/step_<n>.tmp/  →  (atomic rename)  →  <dir>/step_<n>/
    manifest.json       {step, leaf names/shapes/dtypes, treedef repr}
    arrays.npz          one entry per pytree leaf (full logical arrays)

Design choices for scale:
* Full logical arrays + JSON manifest = checkpoints are **mesh-agnostic**:
  restore onto any mesh/device-count (see reshard.py) — the elastic-scaling
  path. On a real multi-host pod each host would write its owned shards with
  the same manifest; the container has one process so arrays are whole.
* **Async**: ``save`` snapshots to host numpy synchronously (cheap, avoids
  mutation races) and a daemon thread does the disk I/O; ``wait()`` joins.
* **Atomic**: write into ``.tmp`` then ``os.rename`` — a crash mid-write
  never corrupts the latest checkpoint; restore picks the newest complete.
* **Retention**: keeps the last ``keep`` checkpoints.
* **Integrity**: the manifest stores a per-leaf SHA-256; ``restore``
  verifies every leaf and, when no explicit step is requested, walks
  back to the newest checkpoint that is both readable and
  checksum-clean (``CheckpointCorrupt`` names the first mismatch) —
  a truncated/bit-rotted ``arrays.npz`` is skipped, never loaded as
  garbage state.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


class CheckpointCorrupt(Exception):
    """A checkpoint directory exists but fails integrity verification
    (unreadable archive, missing leaves, or a SHA-256 mismatch)."""


# Leaves that are per-step scratch, not state: their contents are fully
# rewritten every step (the pack staging pool rides in TrainState only
# for buffer donation) and their shape follows the mesh's data degree —
# persisting them would both waste checkpoint bytes and pin the mesh
# shape, breaking elastic restore. Saved as empty placeholders (marked
# in the manifest) and restored from the live ``like`` state, which
# already has the right shape for the current mesh.
SCRATCH_LEAF_NAMES = ("staging",)


def _is_scratch(name: str) -> bool:
    return name.split("/")[-1] in SCRATCH_LEAF_NAMES


def assert_flushed_state(state: Any, what: str = "checkpoint") -> None:
    """Reject a TrainState carrying a live cross-step pipeline lane
    (``state.inflight`` with leaves): its deferred tail-bucket updates
    exist nowhere but in the scan carry, so persisting (or restarting
    from) it would silently drop them. ``build_train_window`` flushes at
    window edges — any state that legitimately reaches a save is
    flushed. Duck-typed: states without an ``inflight`` field (plain
    dicts, legacy tuples) pass untouched."""
    lane = getattr(state, "inflight", ())
    if jax.tree_util.tree_leaves(lane):
        raise ValueError(
            f"state carries an in-flight pipeline lane; {what} requires "
            "a flushed state (use the state a build_train_window call "
            "returned, not a mid-window carry)")


def _sha256(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()


def _leaf_names(tree: Any) -> List[str]:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        names.append("/".join(parts) if parts else "leaf")
    return names


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        assert_flushed_state(state, what="CheckpointManager.save")
        self.wait()  # at most one in-flight save
        leaves = jax.tree_util.tree_leaves(state)
        names = _leaf_names(state)
        host = [np.zeros((0,), np.asarray(jax.device_get(x)).dtype)
                if _is_scratch(n) else np.asarray(jax.device_get(x))
                for n, x in zip(names, leaves)]
        manifest = {
            "step": int(step),
            "leaves": [{"name": n, "shape": list(a.shape),
                        "dtype": str(a.dtype), "sha256": _sha256(a),
                        **({"scratch": True} if _is_scratch(n) else {})}
                       for n, a in zip(names, host)],
        }

        def _write():
            try:
                tmp = os.path.join(self.directory, f"step_{step}.tmp")
                final = os.path.join(self.directory, f"step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"),
                         **{f"leaf_{i}": a for i, a in enumerate(host)})
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # propagated on next wait()
                self._error = e

        if blocking:
            _write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self.available_steps())
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def available_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                path = os.path.join(self.directory, name, "manifest.json")
                if os.path.exists(path):
                    out.append(int(name[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def _load_verified(self, step: int) -> Tuple[Dict, List[np.ndarray]]:
        """Read one checkpoint and verify every leaf against its manifest
        SHA-256. Any read failure or checksum mismatch raises
        ``CheckpointCorrupt`` (manifests predating the checksum field
        skip verification for that leaf)."""
        path = os.path.join(self.directory, f"step_{step}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            data = np.load(os.path.join(path, "arrays.npz"))
            leaves = [data[f"leaf_{i}"]
                      for i in range(len(manifest["leaves"]))]
        except CheckpointCorrupt:
            raise
        except Exception as e:
            raise CheckpointCorrupt(
                f"step {step}: unreadable ({type(e).__name__}: {e})") from e
        for a, meta in zip(leaves, manifest["leaves"]):
            want = meta.get("sha256")
            if want is not None and _sha256(a) != want:
                raise CheckpointCorrupt(
                    f"step {step}: leaf {meta['name']} SHA-256 mismatch")
        return manifest, leaves

    def restore(self, like: Any, step: Optional[int] = None
                ) -> Tuple[int, Any]:
        """Restore into the structure of ``like`` (values replaced).

        ``step=None`` walks the available checkpoints newest-first and
        loads the first one that verifies — a corrupt latest checkpoint
        (truncated archive, flipped bits) is skipped, not loaded. An
        explicit ``step`` is strict: corruption raises
        ``CheckpointCorrupt``."""
        if step is not None:
            manifest, leaves = self._load_verified(step)
        else:
            steps = self.available_steps()
            if not steps:
                raise FileNotFoundError(
                    f"no checkpoints in {self.directory}")
            last_err: Optional[CheckpointCorrupt] = None
            manifest = None
            for cand in reversed(steps):
                try:
                    manifest, leaves = self._load_verified(cand)
                    step = cand
                    break
                except CheckpointCorrupt as e:
                    last_err = e
            if manifest is None:
                raise CheckpointCorrupt(
                    f"no valid checkpoint in {self.directory} "
                    f"(last error: {last_err})")
        treedef = jax.tree_util.tree_structure(like)
        want = jax.tree_util.tree_leaves(like)
        assert len(want) == len(leaves), (
            f"checkpoint has {len(leaves)} leaves, state needs {len(want)}")
        out = []
        for w, l, meta in zip(want, leaves, manifest["leaves"]):
            if meta.get("scratch"):
                out.append(w)  # live shape wins; contents are per-step
                continue
            assert tuple(w.shape) == tuple(l.shape), (
                f"{meta['name']}: shape {l.shape} != expected {w.shape}")
            out.append(l)
        return step, jax.tree_util.tree_unflatten(treedef, out)
